import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import dataclasses
from repro.configs.archs import ARCHS
from repro.models import params as pm
from repro.distributed.axes import SINGLE, Axes
from repro.training.train_step import TrainHyper, TrainState, make_train_step
from repro.training.optimizer import adamw_init
from repro.launch.mesh import make_mesh
from repro.launch.spmd import build_train_step, state_pspecs, batch_pspec
from repro.training.compression import init_error_feedback

def run_arch(name, mesh_shape=(2,2), axes=("data","model")):
    cfg0 = ARCHS[name].reduced()
    moe = None if cfg0.moe is None else dataclasses.replace(
        cfg0.moe, capacity_factor=cfg0.moe.n_experts / cfg0.moe.top_k)
    cfg = dataclasses.replace(cfg0, param_dtype="float32", moe=moe)
    key = jax.random.PRNGKey(42)
    params = pm.init_params(cfg, key)
    B, S = 4, 32
    S_txt = S - (cfg.vlm_prefix or 0)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_txt)), jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S_txt)), jnp.int32)}
    if cfg.vlm_prefix:
        batch["prefix_embeds"] = jnp.asarray(rng.normal(size=(B, cfg.vlm_prefix, cfg.d_model))*0.02, jnp.float32)
    if cfg.enc_dec:
        batch["frames"] = jnp.asarray(rng.normal(size=(B, cfg.enc_seq, cfg.d_model))*0.02, jnp.float32)

    hyper = TrainHyper(aux_weight=0.0)
    # single device
    state0 = TrainState(params, adamw_init(params, cfg.opt_state_dtype), init_error_feedback(params))
    step1 = jax.jit(make_train_step(cfg, SINGLE, pm.MeshSizes(), hyper))
    s1, m1 = step1(state0, batch)

    # sharded
    mesh = make_mesh(mesh_shape, axes)
    stepN, st_spec, b_spec = build_train_step(cfg, mesh, hyper)
    put = lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp))
    stateS = jax.tree.map(put, state0, st_spec)
    batchS = jax.tree.map(put, batch, b_spec)
    sN, mN = stepN(stateS, batchS)

    dl = abs(float(m1["loss"]) - float(mN["loss"]))
    # compare updated params
    f1 = jax.tree.leaves(s1.params); fN = jax.tree.leaves(jax.device_get(sN.params))
    maxd = max(float(np.abs(np.asarray(a)-np.asarray(b)).max()) for a,b in zip(f1,fN))
    gn = abs(float(m1["grad_norm"]) - float(mN["grad_norm"]))
    print(f"{name:22s} dloss={dl:.2e} dgnorm={gn:.2e} dparams={maxd:.2e}")
    assert dl < 1e-5 and maxd < 5e-4 and gn < 1e-3, (dl, gn, maxd)

for name in ["stablelm-3b", "mixtral-8x22b", "mamba2-370m", "recurrentgemma-9b", "whisper-tiny", "paligemma-3b"]:
    run_arch(name)
print("SPMD EQUIVALENCE OK")
