"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see 1 device
(the 512-device override belongs only to launch/dryrun.py and subprocess
tests)."""
import numpy as np
import pytest


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
