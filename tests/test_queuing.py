"""Queuing network (eqs. 1-7): worked example + structural properties."""
import math

import numpy as np
import pytest

try:  # hypothesis fuzz tests are optional (requirements-dev.txt)
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.queuing import (
    TwoTierModel, mgk_queue, mm1_queue, mmk_queue, service_time_model,
    system_service_rate,
)


def test_paper_worked_example():
    """§V: lam=100, mu1=1000, mu2=33, p12=0.2 => lam_eff=86.6,
    rho1=0.0866, rho2~0.6, T=28.8s, response=2.5s."""
    m = TwoTierModel(lam=100, mu1=1000, mu2=33, p12=0.2, k=1)
    r = m.analyze()
    s = r.summary()
    assert abs(s["lam_eff"] - 86.6) < 1e-9
    assert abs(s["rho1"] - 0.0866) < 1e-4
    assert abs(s["rho2"] - 20 / 33) < 1e-9
    assert r.equilibrium
    assert s["L1"] < 0.01  # "expected length of the tier 1 queue is almost 0"
    t = m.time_for(2500)
    assert abs(t["arrival_window_s"] - 2500 / 86.6) < 1e-9
    assert abs(t["response_time_s"] - 2.5) < 1e-12


def test_service_time_model_eq1_to_4():
    st_ = service_time_model(
        n_read=[1000, 2000], n_write=[0, 0], n_miss=[100, 50],
        mu1_read=1000.0, mu1_write=500.0, mu2=25.0,
    )
    assert st_.t_hit[0] == 1.0 and st_.t_hit[1] == 2.0
    assert st_.t_miss[0] == 4.0 and st_.t_miss[1] == 2.0
    assert st_.t_proc[0] == 4.0  # miss-bound (paper workload1 regime)
    assert st_.t_total == 4.0


def test_mmk_reduces_to_mm1():
    a = mm1_queue(3.0, 5.0)
    b = mmk_queue(3.0, 5.0, 1)
    assert abs(a.lq - b.lq) < 1e-9
    assert abs(a.wq - b.wq) < 1e-9


def test_mgk_exponential_matches_mmk():
    lam, mu, k = 5.0, 2.0, 4
    mean_s = 1.0 / mu
    exp_var = mean_s**2  # exponential service: C_s^2 = 1
    a = mgk_queue(lam, mean_s, exp_var, k)
    b = mmk_queue(lam, mu, k)
    assert abs(a.lq - b.lq) < 1e-9


if HAVE_HYPOTHESIS:

    @given(lam=st.floats(0.1, 50), mu=st.floats(0.1, 50))
    @settings(max_examples=50, deadline=None)
    def test_mm1_littles_law(lam, mu):
        q = mm1_queue(lam, mu)
        if q.stable:
            # Little's law: L = lam * W
            assert abs(q.l - lam * q.w) < 1e-6 * max(1.0, q.l)
            assert q.rho < 1.0
        else:
            assert lam >= mu


def test_overload_flagged_unstable():
    m = TwoTierModel(lam=100, mu1=1000, mu2=10, p12=0.5, k=1)
    assert not m.analyze().equilibrium  # miss queue overloaded (50 > 10)


def test_system_rate_harmonic_bounds():
    mu = system_service_rate(1000.0, 33.0, 0.2)
    assert 33.0 < mu < 1000.0
