"""Queuing network (eqs. 1-7): worked example + structural properties."""
import math

import numpy as np
import pytest

try:  # hypothesis fuzz tests are optional (requirements-dev.txt)
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.queuing import (
    TwoTierModel, mgk_queue, mm1_queue, mmk_queue, service_time_model,
    system_service_rate,
)


def test_paper_worked_example():
    """§V: lam=100, mu1=1000, mu2=33, p12=0.2 => lam_eff=86.6,
    rho1=0.0866, rho2~0.6, T=28.8s, response=2.5s."""
    m = TwoTierModel(lam=100, mu1=1000, mu2=33, p12=0.2, k=1)
    r = m.analyze()
    s = r.summary()
    assert abs(s["lam_eff"] - 86.6) < 1e-9
    assert abs(s["rho1"] - 0.0866) < 1e-4
    assert abs(s["rho2"] - 20 / 33) < 1e-9
    assert r.equilibrium
    assert s["L1"] < 0.01  # "expected length of the tier 1 queue is almost 0"
    t = m.time_for(2500)
    assert abs(t["arrival_window_s"] - 2500 / 86.6) < 1e-9
    assert abs(t["response_time_s"] - 2.5) < 1e-12


def test_service_time_model_eq1_to_4():
    st_ = service_time_model(
        n_read=[1000, 2000], n_write=[0, 0], n_miss=[100, 50],
        mu1_read=1000.0, mu1_write=500.0, mu2=25.0,
    )
    assert st_.t_hit[0] == 1.0 and st_.t_hit[1] == 2.0
    assert st_.t_miss[0] == 4.0 and st_.t_miss[1] == 2.0
    assert st_.t_proc[0] == 4.0  # miss-bound (paper workload1 regime)
    assert st_.t_total == 4.0


def test_mmk_reduces_to_mm1():
    a = mm1_queue(3.0, 5.0)
    b = mmk_queue(3.0, 5.0, 1)
    assert abs(a.lq - b.lq) < 1e-9
    assert abs(a.wq - b.wq) < 1e-9


def test_mgk_exponential_matches_mmk():
    lam, mu, k = 5.0, 2.0, 4
    mean_s = 1.0 / mu
    exp_var = mean_s**2  # exponential service: C_s^2 = 1
    a = mgk_queue(lam, mean_s, exp_var, k)
    b = mmk_queue(lam, mu, k)
    assert abs(a.lq - b.lq) < 1e-9


if HAVE_HYPOTHESIS:

    @given(lam=st.floats(0.1, 50), mu=st.floats(0.1, 50))
    @settings(max_examples=50, deadline=None)
    def test_mm1_littles_law(lam, mu):
        q = mm1_queue(lam, mu)
        if q.stable:
            # Little's law: L = lam * W
            assert abs(q.l - lam * q.w) < 1e-6 * max(1.0, q.l)
            assert q.rho < 1.0
        else:
            assert lam >= mu


def test_overload_flagged_unstable():
    m = TwoTierModel(lam=100, mu1=1000, mu2=10, p12=0.5, k=1)
    assert not m.analyze().equilibrium  # miss queue overloaded (50 > 10)


def test_system_rate_harmonic_bounds():
    mu = system_service_rate(1000.0, 33.0, 0.2)
    assert 33.0 < mu < 1000.0


# --- vectorized formulas vs the scalar reference ---------------------------
#
# Independent scalar reimplementations of the closed forms (the pre-refactor
# float-math code), used as golden references for the numpy-vectorized
# implementations on randomized stable/unstable/idle inputs.


def _ref_mm1(lam, mu):
    if lam <= 0.0:
        return (0.0, 1.0, 0.0, 0.0, 0.0, 1.0 / mu, True)
    rho = lam / mu
    if rho >= 1.0:
        return (rho, 0.0, math.inf, math.inf, math.inf, math.inf, False)
    lq = rho * rho / (1.0 - rho)
    l = rho / (1.0 - rho)
    return (rho, 1.0 - rho, lq, l, lq / lam, l / lam, True)


def _ref_mmk(lam, mu, k):
    if lam <= 0.0:
        return (0.0, 1.0, 0.0, 0.0, 0.0, 1.0 / mu, True)
    a = lam / mu
    rho = a / k
    if rho >= 1.0:
        return (rho, 0.0, math.inf, math.inf, math.inf, math.inf, False)
    s = sum(a**i / math.factorial(i) for i in range(k))
    s += a**k / (math.factorial(k) * (1.0 - a / k))
    p0 = 1.0 / s
    lq = p0 * a ** (k + 1) / (math.factorial(k - 1) * (k - a) ** 2)
    l = lq + a
    return (rho, p0, lq, l, lq / lam, l / lam, True)


def _ref_mgk(lam, mean_s, var_s, k):
    base = _ref_mmk(lam, 1.0 / mean_s, k)
    if not base[-1] or lam <= 0.0:
        return base
    cs2 = var_s / (mean_s * mean_s)
    lq = base[2] * (1.0 + cs2) / 2.0
    l = lq + lam * mean_s
    return (base[0], base[1], lq, l, lq / lam, l / lam, True)


def _rand_rates(rng, n):
    """Arrival/service grids spanning idle, stable and saturated regimes."""
    lam = rng.uniform(-1.0, 30.0, size=n)  # negatives exercise the idle path
    lam[rng.random(n) < 0.15] = 0.0
    mu = rng.uniform(0.5, 20.0, size=n)
    return lam, mu


def _assert_matches_ref(vec, refs):
    for field, got in zip(vec._fields, vec):
        want = np.asarray([r[vec._fields.index(field)] for r in refs])
        np.testing.assert_allclose(
            np.asarray(got, float), np.asarray(want, float),
            rtol=1e-12, atol=0.0, err_msg=field)


def test_mm1_vectorized_matches_scalar_reference():
    rng = np.random.default_rng(0)
    lam, mu = _rand_rates(rng, 200)
    vec = mm1_queue(lam, mu)
    refs = [_ref_mm1(la, m) for la, m in zip(lam, mu)]
    _assert_matches_ref(vec, refs)
    assert not np.asarray(vec.stable).all()  # grid really spans both regimes
    assert np.asarray(vec.stable).any()


@pytest.mark.parametrize("k", [1, 2, 4, 7])
def test_mmk_vectorized_matches_scalar_reference(k):
    rng = np.random.default_rng(k)
    lam, mu = _rand_rates(rng, 200)
    vec = mmk_queue(lam, mu, k)
    refs = [_ref_mmk(la, m, k) for la, m in zip(lam, mu)]
    _assert_matches_ref(vec, refs)


@pytest.mark.parametrize("k", [1, 3])
def test_mgk_vectorized_matches_scalar_reference(k):
    rng = np.random.default_rng(10 + k)
    lam, mu = _rand_rates(rng, 150)
    mean_s = 1.0 / mu
    var_s = rng.uniform(0.0, 3.0, size=150) * mean_s**2
    vec = mgk_queue(lam, mean_s, var_s, k)
    refs = [_ref_mgk(la, m, v, k)
            for la, m, v in zip(lam, mean_s, var_s)]
    _assert_matches_ref(vec, refs)


def test_vectorized_two_tier_matches_scalar_loop():
    """TwoTierModel over [points] arrays == a Python loop of scalar models."""
    rng = np.random.default_rng(42)
    n = 64
    lam = rng.uniform(1.0, 300.0, size=n)
    mu1 = rng.uniform(200.0, 2000.0, size=n)
    mu2 = rng.uniform(5.0, 60.0, size=n)
    p12 = rng.uniform(0.0, 1.0, size=n)
    for flow in ("paper", "conserving"):
        vec = TwoTierModel(lam=lam, mu1=mu1, mu2=mu2, p12=p12,
                           flow=flow).analyze()
        vs = vec.summary()
        for i in range(n):
            ref = TwoTierModel(lam=float(lam[i]), mu1=float(mu1[i]),
                               mu2=float(mu2[i]), p12=float(p12[i]),
                               flow=flow).analyze()
            rs = ref.summary()
            for key in ("lam_eff", "rho1", "rho2", "L1", "W1", "L2", "W2",
                        "mu_system", "rho_system", "equilibrium"):
                np.testing.assert_allclose(
                    np.asarray(vs[key])[i], rs[key], rtol=1e-12,
                    err_msg=f"{flow}:{key}[{i}]")


def test_mixed_var_s1_dispatches_elementwise():
    """Regression: an array var_s1 mixing zeros and positives must apply
    M/M/k to the zero-variance elements (docstring contract: 0 =>
    exponential), not Allen-Cunneen with C_s^2 = 0."""
    lam = np.array([50.0, 50.0])
    mu1 = np.array([500.0, 500.0])
    mixed = TwoTierModel(lam=lam, mu1=mu1, mu2=30.0, p12=0.2, k=2,
                         var_s1=np.array([0.0, 1e-5])).analyze()
    pure_mmk = TwoTierModel(lam=50.0, mu1=500.0, mu2=30.0, p12=0.2, k=2,
                            var_s1=0.0).analyze()
    pure_mgk = TwoTierModel(lam=50.0, mu1=500.0, mu2=30.0, p12=0.2, k=2,
                            var_s1=1e-5).analyze()
    assert np.asarray(mixed.q1.lq)[0] == pytest.approx(pure_mmk.q1.lq)
    assert np.asarray(mixed.q1.lq)[1] == pytest.approx(pure_mgk.q1.lq)
    assert np.asarray(mixed.q1.stable).dtype == bool
    # Regression: scalar lam with a wider var_s1 array must broadcast, not
    # crash in the scalar/array output dispatch.
    wide = TwoTierModel(lam=50.0, mu1=500.0, mu2=30.0, p12=0.2, k=2,
                        var_s1=np.array([0.0, 1e-5])).analyze()
    assert np.asarray(wide.q1.lq).shape == (2,)
    assert np.asarray(wide.q1.lq)[0] == pytest.approx(pure_mmk.q1.lq)
    assert np.asarray(wide.q1.lq)[1] == pytest.approx(pure_mgk.q1.lq)
    direct = mgk_queue(50.0, 0.002, np.array([1e-5, 2e-5]), 2)
    assert np.asarray(direct.lq).shape == (2,)


def test_scalar_inputs_return_plain_floats():
    q = mm1_queue(3.0, 5.0)
    assert all(isinstance(v, float) for v in q[:-1])
    assert isinstance(q.stable, bool)
    q = mmk_queue(0.0, 5.0, 3)
    assert isinstance(q.w, float) and q.w == 0.2


if HAVE_HYPOTHESIS:

    @given(lam=st.floats(0.0, 100.0), mu=st.floats(0.1, 50.0),
           k=st.integers(1, 6))
    @settings(max_examples=60, deadline=None)
    def test_mmk_fuzz_matches_reference(lam, mu, k):
        vec = mmk_queue(np.asarray([lam]), np.asarray([mu]), k)
        ref = _ref_mmk(lam, mu, k)
        _assert_matches_ref(vec, [ref])
