"""Per-arch smoke tests: reduced config, one forward + one train step on
CPU, asserting output shapes and no NaNs (deliverable f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.configs.base import SHAPES
from repro.distributed.axes import SINGLE
from repro.models import params as pm
from repro.models.transformer import fwd_train
from repro.training.compression import init_error_feedback
from repro.training.optimizer import adamw_init
from repro.training.train_step import TrainHyper, TrainState, make_train_step


def _batch(cfg, rng, B=2, S=32):
    s_txt = S - (cfg.vlm_prefix or 0)
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, s_txt)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, s_txt)), jnp.int32),
    }
    if cfg.vlm_prefix:
        b["prefix_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.vlm_prefix, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.enc_dec:
        b["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.enc_seq, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_and_train_step(arch, rng):
    cfg = ARCHS[arch].reduced()
    params = pm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    loss, metrics = jax.jit(
        lambda p, b: fwd_train(p, b, cfg, SINGLE))(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0

    state = TrainState(params, adamw_init(params, cfg.opt_state_dtype),
                       init_error_feedback(params))
    step = jax.jit(make_train_step(cfg, SINGLE, pm.MeshSizes(), TrainHyper()))
    new_state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["grad_norm"]))
    # params actually changed somewhere (bf16 rounding can freeze O(1)-magnitude
    # leaves at lr=3e-4, so check across the whole tree)
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params))
    )
    assert changed


def test_all_archs_and_shapes_registered():
    assert len(ARCHS) == 10
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    for n, cfg in ARCHS.items():
        assert cfg.n_layers > 0 and cfg.vocab > 0, n


def test_exact_published_configs():
    a = ARCHS["llama3-405b"]
    assert (a.n_layers, a.d_model, a.n_heads, a.n_kv_heads, a.d_ff,
            a.vocab) == (126, 16384, 128, 8, 53248, 128256)
    g = ARCHS["grok-1-314b"]
    assert g.moe.n_experts == 8 and g.moe.top_k == 2
    r = ARCHS["recurrentgemma-9b"]
    assert r.block_pattern == ("rglru", "rglru", "attn_local")
    m = ARCHS["mamba2-370m"]
    assert m.d_ff == 0 and m.ssm.state_dim == 128


def test_microbatch_accumulation_matches(rng):
    cfg = dataclasses.replace(ARCHS["stablelm-3b"].reduced(),
                              param_dtype="float32")
    params = pm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng, B=4)
    state = TrainState(params, adamw_init(params, "float32"),
                       init_error_feedback(params))
    s1 = jax.jit(make_train_step(cfg, SINGLE, pm.MeshSizes(),
                                 TrainHyper(accum_steps=1)))
    s2 = jax.jit(make_train_step(cfg, SINGLE, pm.MeshSizes(),
                                 TrainHyper(accum_steps=2)))
    out1, m1 = s1(state, batch)
    out2, m2 = s2(state, batch)
    # losses computed over the same tokens; accumulation averages microbatches
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
