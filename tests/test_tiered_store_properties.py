"""Property-based invariants for the tier-1 store across policies and
traffic generators (ISSUE 2 satellite).

The parametrized grid below always runs (no extra deps); when hypothesis
is installed an additional fuzz pass explores random generator settings.
"""
import numpy as np
import pytest

from repro.core.traffic import (
    irm_stream,
    markov_stream,
    poisson_stream,
    strided_stream,
)
from repro.storage.tiered_store import (
    StoreConfig,
    partition_streams,
    run_distributed,
    run_stream,
)

POLICIES = ("ws", "lru", "lfu", "random")
GENERATORS = {
    "poisson": poisson_stream,
    "irm": irm_stream,
    "strided": strided_stream,
    "markov": markov_stream,
}
N, N_PAGES = 400, 128


def check_stream_invariants(st, n_requests: int):
    hits, misses = int(st.hits), int(st.misses)
    assert hits >= 0 and misses >= 0
    assert hits + misses == n_requests
    assert int(st.tier2_reads) >= misses - int(st.prefetch_hits)
    assert int(st.evictions) <= misses
    assert int(st.tier2_writes) <= int(st.evictions)
    assert int(st.prefetch_hits) <= misses


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("kind", sorted(GENERATORS))
def test_single_shard_invariants(policy, kind):
    pages, writes = GENERATORS[kind](N, N_PAGES, seed=5, write_fraction=0.25)
    cfg = StoreConfig(n_lines=32, policy=policy, prefetch=(kind == "strided"))
    st = run_stream(cfg, pages, writes)
    check_stream_invariants(st, N)
    if kind == "strided" and policy == "lru":
        # The stream identifier must convert some misses into buffer hits.
        assert int(st.prefetch_hits) > 0


@pytest.mark.parametrize("policy", ("ws", "lru"))
@pytest.mark.parametrize("kind", sorted(GENERATORS))
@pytest.mark.parametrize("mapping", ("block", "random"))
def test_distributed_invariants(policy, kind, mapping):
    """Padding correction never yields negative/impossible per-shard stats."""
    pages, writes = GENERATORS[kind](N, N_PAGES, seed=9, write_fraction=0.25)
    n_shards = 4
    stats, counts = run_distributed(
        StoreConfig(n_lines=16, policy=policy),
        pages, writes, n_shards=n_shards, mapping=mapping, n_pages=N_PAGES,
    )
    hits = np.asarray(stats.hits)
    misses = np.asarray(stats.misses)
    assert int(counts.sum()) == N
    assert (hits >= 0).all()
    assert (misses >= 0).all()
    # Padded requests are pure hits: after correction the per-shard
    # counters balance exactly against the real request counts.
    np.testing.assert_array_equal(hits + misses, counts)
    assert (np.asarray(stats.evictions) <= misses).all()
    assert (np.asarray(stats.tier2_writes) <= np.asarray(stats.evictions)).all()
    assert (np.asarray(stats.tier2_reads)
            >= misses - np.asarray(stats.prefetch_hits)).all()


def test_partition_streams_exact():
    pages, writes = irm_stream(N, N_PAGES, seed=2, write_fraction=0.5)
    sh_pages, sh_writes, counts, owner = partition_streams(
        pages, writes, n_shards=4, mapping="block", n_pages=N_PAGES
    )
    assert sh_pages.shape == sh_writes.shape == (4, counts.max())
    assert counts.sum() == N
    # Every request lands on its owner shard, order preserved.
    for s in range(4):
        sel = owner == s
        np.testing.assert_array_equal(sh_pages[s, : counts[s]], pages[sel])
        np.testing.assert_array_equal(sh_writes[s, : counts[s]], writes[sel])
        # Padding repeats the last page (a guaranteed hit).
        if counts[s] and counts[s] < sh_pages.shape[1]:
            assert (sh_pages[s, counts[s]:] == pages[sel][-1]).all()


def test_partition_streams_cap_too_small():
    pages, writes = irm_stream(N, N_PAGES, seed=2)
    with pytest.raises(ValueError):
        partition_streams(pages, writes, n_shards=2, n_pages=N_PAGES, cap=1)


# --- optional hypothesis fuzz over generator/engine settings ---------------
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @given(
        policy=st.sampled_from(POLICIES),
        kind=st.sampled_from(sorted(GENERATORS)),
        n_lines=st.sampled_from([8, 32, 64]),
        write_fraction=st.floats(0.0, 1.0),
        seed=st.integers(0, 100),
    )
    @settings(max_examples=20, deadline=None)
    def test_invariants_fuzz(policy, kind, n_lines, write_fraction, seed):
        pages, writes = GENERATORS[kind](
            200, 64, seed=seed, write_fraction=write_fraction
        )
        cfg = StoreConfig(n_lines=n_lines, policy=policy, prefetch=True)
        check_stream_invariants(run_stream(cfg, pages, writes), 200)

except ImportError:  # covered by the parametrized grid above
    pass
