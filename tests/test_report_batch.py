"""Batched report pipeline (ISSUE 9): ``batched_reports`` /
``sweep(report=...)`` equivalence, the per-stage profile, the duration
guard, and the μ(Q) spec plumbing.

The scalar solver path must stay *bit-identical* to the pre-batching
``report_from_counters`` (that is the mu_load-off guarantee at the report
level), the batched path must agree to solver precision with identical
onset/metastability verdicts, and ``sweep`` must accept an explicit list
of override dicts (the capacity planner's entry point).
"""
import json

import numpy as np
import pytest

from repro.core.traffic import TrafficSpec
from repro.sim import (
    FaultSpec,
    RateSpec,
    RetryPolicy,
    SimSpec,
    batched_reports,
    device_degrade,
    report_from_counters,
    shard_down,
    simulate,
    sweep,
    tier1_counters,
)


def _spec(lam=60.0, mu2=40.0, faulted=True, n_windows=10, **kw):
    faults = None
    if faulted:
        faults = FaultSpec(
            events=(shard_down(1, 0.1, 0.3),
                    device_degrade(2, 0.5, 0.15, 0.4)),
            retry=RetryPolicy(timeout=0.05, max_retries=2,
                              backoff_init=0.3),
        )
    return SimSpec(
        traffic=TrafficSpec(kind="poisson", n_requests=1500, n_pages=256,
                            rate=240.0, seed=5),
        n_shards=4, lam=lam,
        rates=RateSpec(mu1=400.0, mu2=mu2),
        n_windows=n_windows, window_dt=0.05,
        faults=faults, **kw,
    )


def _report_json(rep) -> str:
    def jsonify(o):
        if isinstance(o, np.ndarray):
            return o.tolist()
        if isinstance(o, np.generic):
            return o.item()
        raise TypeError(type(o))
    return json.dumps(rep.to_dict(), sort_keys=True, default=jsonify)


def _assert_reports_close(a, b, tol=1e-10):
    for name in ("q1", "q2", "w1", "w2", "response", "rho1", "rho2"):
        xa = np.asarray(getattr(a.transient, name), float)
        xb = np.asarray(getattr(b.transient, name), float)
        fa, fb = np.isfinite(xa), np.isfinite(xb)
        np.testing.assert_array_equal(fa, fb, err_msg=name)
        if fa.any():
            np.testing.assert_allclose(xa[fa], xb[fb], rtol=0, atol=tol,
                                       err_msg=name)
    assert a.saturation_onset == b.saturation_onset
    assert a.metastable_onset == b.metastable_onset
    for sa, sb in zip(a.shards, b.shards):
        assert sa.saturation_onset == sb.saturation_onset
        assert sa.metastable_onset == sb.metastable_onset
    assert a.response_s == pytest.approx(b.response_s, abs=tol)


def test_scalar_solver_bit_identical_to_reference():
    """batched_reports(solver='scalar') is the pre-batching per-point path,
    byte for byte — the refactor must not move the default output."""
    specs = [_spec(lam=l, faulted=f)
             for l in (40.0, 80.0) for f in (False, True)]
    items = [(s, tier1_counters(s), None) for s in specs]
    ref = [report_from_counters(s, c, t) for s, c, t in items]
    got = batched_reports(items, solver="scalar")
    for a, b in zip(ref, got):
        assert _report_json(a) == _report_json(b)


def test_batched_matches_scalar_reports():
    specs = [_spec(lam=l, mu2=m, faulted=f)
             for l in (40.0, 90.0) for m in (30.0, 55.0)
             for f in (False, True)]
    items = [(s, tier1_counters(s), None) for s in specs]
    scalar = batched_reports(items, solver="scalar")
    batched = batched_reports(items, solver="batched")
    for a, b in zip(scalar, batched):
        _assert_reports_close(a, b)


def test_batched_reports_validation_and_piecewise_fallback():
    with pytest.raises(ValueError, match="solver"):
        batched_reports([], solver="nope")
    # Piecewise-mode points ride the scalar path inside solver='batched'.
    spec = _spec(faulted=False, transient_mode="piecewise")
    items = [(spec, tier1_counters(spec))]
    a = batched_reports(items, solver="batched")[0]
    b = report_from_counters(*items[0])
    assert _report_json(a) == _report_json(b)


def test_duration_guard_on_timed_specs():
    """A timed spec whose window_dt degenerates to 0/NaN (validation
    bypassed — stale pickles, object.__setattr__) fails loudly in the
    report, not with rates divided by zero."""
    spec = _spec(faulted=False)
    ctr = tier1_counters(spec)
    for bad in (0.0, float("nan")):
        broken = object.__new__(SimSpec)
        object.__setattr__(broken, "__dict__", dict(spec.__dict__))
        object.__setattr__(broken, "window_dt", bad)
        with pytest.raises(ValueError, match="window duration"):
            report_from_counters(broken, ctr)


def test_simspec_rejects_nonfinite_window_dt():
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError, match="window_dt"):
            _spec(faulted=False, n_windows=4).replace(window_dt=bad)


def test_mu_load_requires_fluid_mode():
    rates = RateSpec(mu1=400.0, mu2=40.0, mu_load=((0.01, 0.1), (0.0, 0.2)))
    spec = _spec(faulted=False).replace(rates=rates)
    assert spec.transient_mode == "fluid"  # accepted
    with pytest.raises(ValueError, match="mu_load"):
        spec.replace(transient_mode="piecewise")


def test_mu_load_rides_report_and_batches_separately():
    """A μ(Q)-enabled spec solves end to end on both report paths (they
    agree), lands in its own batch group, and bends the transient vs the
    fixed-rate solve."""
    base = _spec(faulted=False)
    slow = base.replace(
        rates=RateSpec(mu1=400.0, mu2=40.0,
                       mu_load=((0.0, 0.5), (0.0, 0.5))))
    ctr = tier1_counters(base)  # same traffic: counters shared
    items = [(base, ctr), (slow, ctr)]
    scalar = batched_reports(items, solver="scalar")
    batched = batched_reports(items, solver="batched")
    for a, b in zip(scalar, batched):
        _assert_reports_close(a, b)
    q_base = np.asarray(batched[0].transient.q1)
    q_slow = np.asarray(batched[1].transient.q1)
    assert q_slow.max() > q_base.max()


def test_sweep_report_modes_and_profile():
    base = _spec()
    axes = {"lam": [40.0, 70.0], "rates.mu2": [30.0, 50.0]}
    rb = sweep(base, axes, report="batched", profile=True)
    rs = sweep(base, axes, report="scalar")
    for a, b in zip(rs.reports, rb.reports):
        _assert_reports_close(a, b)
    assert rs.profile is None
    prof = rb.profile
    assert set(prof) >= {"stream_gen", "engine_dispatch", "report_solve",
                         "assembly", "total", "n_points"}
    assert prof["n_points"] == 4
    assert all(prof[k] >= 0 for k in ("stream_gen", "engine_dispatch",
                                      "report_solve", "assembly"))
    payload = json.loads(rb.to_json())
    assert payload["profile"]["report_solver"] == "batched"
    with pytest.raises(ValueError, match="report"):
        sweep(base, axes, report="nope")


def test_sweep_accepts_explicit_point_list():
    base = _spec(faulted=False)
    pts = [{"lam": 45.0}, {"lam": 85.0, "rates.mu2": 30.0}]
    res = sweep(base, pts, report="batched")
    assert res.points == (pts[0], pts[1])
    assert res.axes == {}
    direct = simulate(base.replace(**pts[1]))
    assert res.reports[1].misses == direct.misses
    assert res.reports[1].lam_eff == pytest.approx(direct.lam_eff)
